"""Durability layer: deterministic faults, checkpoint/restore, bounded
caches — and the exact-parity contract holding THROUGH all of them.

Three claims under test:

1. `FaultInjector` schedules are pure functions of (seed, site, per-site
   check index) — reproducible and independent of cross-site
   interleaving — and every instrumented session call fails BEFORE
   mutating state, so a faulted operation is cleanly retryable and the
   retried session stays `==` a fresh `DesignAdvisor`.
2. `AdvisorSession.snapshot()/restore()` round-trips (including through
   `to_bytes`/`from_bytes`) rebuild a session whose next recommendation
   is exactly `==` a fresh advisor on the snapshot workload, with the
   retired-name contract intact.
3. The bounded-memory knobs (`samplecf_cache_entries`,
   `max_planner_nodes`, `max_replay_entries`) only ever discard
   recomputable state: drift runs under absurdly tight bounds keep
   bit-exact parity while the eviction counters prove the bounds bit.

The deterministic suite runs everywhere; the randomized interleaving
property at the bottom is hypothesis-gated like the other property
modules.
"""
import dataclasses
import pickle

import pytest

from repro.core import (AdvisorOptions, AdvisorSession, DesignAdvisor,
                        EstimateCache, FaultError, FaultInjector, FaultSpec,
                        SessionSnapshot, SnapshotCorrupt, WorkloadDelta,
                        base_configuration, make_scaled_workload,
                        make_tpch_like)
from repro.core.faults import SITES
from repro.core.session import (SNAPSHOT_FORMAT_VERSION, SNAPSHOT_MAGIC,
                                _SNAP_HEADER)


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.1, z=0, seed=0)


@pytest.fixture(scope="module")
def workload(schema):
    return make_scaled_workload(schema, n_statements=14, seed=1)


@pytest.fixture(scope="module")
def pool(schema):
    return [dataclasses.replace(s, name=f"p{i:02d}") for i, s in
            enumerate(make_scaled_workload(schema, n_statements=24,
                                           seed=6).statements)]


@pytest.fixture(scope="module")
def budget(schema, workload):
    adv = DesignAdvisor(workload)
    base = sum(adv.sizes.size(i)
               for i in base_configuration(schema).indexes)
    return 0.3 * base


def assert_identical(rec_s, rec_f):
    assert rec_s.config == rec_f.config
    assert rec_s.cost == rec_f.cost
    assert rec_s.used_bytes == rec_f.used_bytes


# Tight-enough-to-evict bounds used throughout: small caches force
# evictions on every drift round while parity must not budge.
TIGHT = dict(samplecf_cache_entries=8, max_planner_nodes=20,
             max_replay_entries=10)


# ---------------------------------------------------------------------------
# FaultInjector determinism
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_same_seed_same_schedule(self):
        specs = {"estimation": 0.2, "apply_delta": 0.1}
        a = FaultInjector(seed=7, specs=specs)
        b = FaultInjector(seed=7, specs=specs)
        sched_a = [(s, a.fires(s)) for _ in range(100) for s in SITES]
        sched_b = [(s, b.fires(s)) for _ in range(100) for s in SITES]
        assert sched_a == sched_b
        assert a.stats() == b.stats()
        assert a.fired["estimation"] > 0     # the rate actually bites

    def test_different_seed_different_schedule(self):
        a = FaultInjector(seed=1, specs={"estimation": 0.2})
        b = FaultInjector(seed=2, specs={"estimation": 0.2})
        assert [a.fires("estimation") for _ in range(200)] != \
               [b.fires("estimation") for _ in range(200)]

    def test_site_streams_independent_of_interleaving(self):
        """A site's fault schedule depends only on its OWN check count —
        interleaving checks at other sites cannot shift it."""
        specs = {"estimation": 0.25, "costing": 0.25}
        solo = FaultInjector(seed=3, specs=specs)
        mixed = FaultInjector(seed=3, specs=specs)
        got_solo = [solo.fires("estimation") for _ in range(64)]
        got_mixed = []
        for i in range(64):
            for _ in range(i % 3):           # varying noise at other sites
                mixed.fires("costing")
            got_mixed.append(mixed.fires("estimation"))
        assert got_solo == got_mixed

    def test_scripted_at_indices(self):
        inj = FaultInjector(specs={"apply_delta": FaultSpec(at=(0, 3))})
        assert [inj.fires("apply_delta") for _ in range(6)] == \
               [True, False, False, True, False, False]

    def test_at_does_not_shift_rate_stream(self):
        """Scripted hits draw from the stream anyway, so adding `at`
        never changes which OTHER checks fire."""
        plain = FaultInjector(seed=5, specs={"estimation": 0.3})
        scripted = FaultInjector(
            seed=5, specs={"estimation": FaultSpec(rate=0.3, at=(4,))})
        a = [plain.fires("estimation") for _ in range(40)]
        b = [scripted.fires("estimation") for _ in range(40)]
        assert b[4] is True
        assert [x for i, x in enumerate(a) if i != 4] == \
               [x for i, x in enumerate(b) if i != 4]

    def test_max_fires_caps_total(self):
        inj = FaultInjector(specs={
            "prefetch": FaultSpec(at=tuple(range(10)), max_fires=3)})
        fires = [inj.fires("prefetch") for _ in range(10)]
        assert sum(fires) == 3 and fires[:3] == [True] * 3
        assert inj.fired["prefetch"] == 3
        assert inj.checks["prefetch"] == 10

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultInjector(specs={"no_such_site": 0.5})

    def test_check_raises_fault_error(self):
        inj = FaultInjector(specs={"costing": FaultSpec(at=(1,))})
        inj.check("costing")                  # check 0: quiet
        with pytest.raises(FaultError, match="costing") as ei:
            inj.check("costing", "during recommend")
        assert ei.value.site == "costing" and ei.value.n == 1
        assert "during recommend" in str(ei.value)

    # The PR 7 sites' schedules for seed=7 at rate 0.5, pinned as
    # literals: adding the disk sites ("disk_write"/"fsync"/"bit_flip")
    # to SITES must leave every pre-existing stream bit-identical,
    # because streams are seeded per site — (seed, crc32(site)) — not
    # by position in SITES.  If this test ever fails, a change broke
    # the per-site seeding and silently reshuffled every storm schedule
    # in the test/benchmark suite.
    LEGACY_SITES = ("estimation", "costing", "planner_replay", "prefetch",
                    "apply_delta")
    PINNED_SEED7_RATE50 = {
        "estimation": "101100011101101100111010",
        "costing": "110011101001000100000000",
        "planner_replay": "000001111000011101111010",
        "prefetch": "000011100010101010100011",
        "apply_delta": "110001100010100111011000",
    }

    def test_legacy_schedules_pinned(self):
        inj = FaultInjector(seed=7,
                            specs={s: 0.5 for s in self.LEGACY_SITES})
        got = {s: "".join("1" if inj.fires(s) else "0"
                          for _ in range(24))
               for s in self.LEGACY_SITES}
        assert got == self.PINNED_SEED7_RATE50

    def test_disk_sites_do_not_shift_legacy_schedules(self):
        """Enabling (and exercising) the disk sites leaves the legacy
        sites' draws untouched — same literals as the pinned test."""
        specs = {s: 0.5 for s in self.LEGACY_SITES}
        specs.update({"disk_write": 0.5, "fsync": 0.5, "bit_flip": 0.5})
        inj = FaultInjector(seed=7, specs=specs)
        got = {}
        for s in self.LEGACY_SITES:
            bits = []
            for i in range(24):
                # noisy interleaved disk-site checks between every draw
                for d in ("disk_write", "fsync", "bit_flip")[:i % 4]:
                    inj.fires(d)
                bits.append("1" if inj.fires(s) else "0")
            got[s] = "".join(bits)
        assert got == self.PINNED_SEED7_RATE50

    def test_disk_sites_registered(self):
        assert SITES[-3:] == ("disk_write", "fsync", "bit_flip")
        inj = FaultInjector(specs={"disk_write": FaultSpec(at=(0,))})
        assert inj.fires("disk_write") is True
        assert inj.fires("fsync") is False   # unspecced sites still count
        assert inj.checks["fsync"] == 1


# ---------------------------------------------------------------------------
# EstimateCache (bounded LRU) semantics
# ---------------------------------------------------------------------------

class TestEstimateCache:
    def test_rejects_nonpositive_maxsize(self):
        with pytest.raises(ValueError):
            EstimateCache(0)

    def test_lru_eviction_order(self):
        c = EstimateCache(2)
        c["a"] = 1
        c["b"] = 2
        assert c["a"] == 1                    # touch: a is now most recent
        c["c"] = 3                            # evicts b, the LRU entry
        assert "b" not in c and "a" in c and "c" in c
        assert c.evictions == 1

    def test_counters_and_pure_contains(self):
        c = EstimateCache(2)
        c["a"] = 1
        c["b"] = 2
        assert c.get("a") == 1 and c.get("zz") is None
        assert (c.hits, c.misses) == (1, 1)       # get("a") made "b" LRU
        # __contains__ is a pure peek: no counters, no recency touch —
        # probing "b" does NOT save it from being the eviction victim
        assert "b" in c
        assert (c.hits, c.misses) == (1, 1)
        c["c"] = 3
        assert "b" not in c and "a" in c
        st = c.stats()
        assert st["maxsize"] == 2 and st["evictions"] == 1

    def test_mutable_mapping_protocol(self):
        c = EstimateCache(4)
        c.update({"x": 1, "y": 2})
        assert len(c) == 2 and sorted(c) == ["x", "y"]
        del c["x"]
        assert "x" not in c and len(c) == 1


# ---------------------------------------------------------------------------
# Session fault sites: fail-before-mutate, so retries are exact
# ---------------------------------------------------------------------------

class TestSessionFaults:
    def test_faulted_apply_leaves_session_retryable(self, workload, pool,
                                                    budget):
        inj = FaultInjector(specs={"apply_delta": FaultSpec(at=(0,))})
        sess = AdvisorSession(workload, faults=inj)
        delta = WorkloadDelta(added=(pool[0],))
        v0 = sess.workload_version
        with pytest.raises(FaultError, match="apply_delta"):
            sess.apply(delta)
        assert sess.workload_version == v0          # untouched
        sess.apply(delta)                           # plain retry works
        fresh = DesignAdvisor(workload.apply_delta(delta))
        assert_identical(sess.recommend(budget), fresh.recommend(budget))

    def test_faulted_recommend_retries_exactly(self, workload, budget):
        for site in ("estimation", "costing"):
            inj = FaultInjector(specs={site: FaultSpec(at=(0,))})
            sess = AdvisorSession(workload, faults=inj)
            with pytest.raises(FaultError, match=site):
                sess.recommend(budget)
            assert_identical(sess.recommend(budget),
                             DesignAdvisor(workload).recommend(budget))

    def test_replay_loss_is_bit_exact(self, workload, pool, budget):
        """A planner_replay fire silently drops the replay store — the
        next recommend recomputes every decision identically."""
        inj = FaultInjector(
            specs={"planner_replay": FaultSpec(at=(1, 2))})
        sess = AdvisorSession(workload, faults=inj)
        plain = AdvisorSession(workload)
        assert_identical(sess.recommend(budget), plain.recommend(budget))
        delta = WorkloadDelta(added=(pool[3],))
        sess.apply(delta)
        plain.apply(delta)
        assert_identical(sess.recommend(budget), plain.recommend(budget))
        st = sess.stats
        assert st["replay_faults"] >= 1

    def test_fault_storm_schedule_reproducible(self, workload, pool,
                                               budget):
        """Two identical sessions under the same seeded storm fail at
        the same operations, and every SURVIVING recommend is `==` the
        fresh advisor."""
        def run(seed):
            inj = FaultInjector(seed=seed, specs={
                "apply_delta": 0.3, "estimation": 0.3, "costing": 0.3})
            sess = AdvisorSession(workload, faults=inj)
            wl, outcomes = workload, []
            for i in range(6):
                delta = WorkloadDelta(added=(pool[6 + i],))
                try:
                    sess.apply(delta)
                    wl = wl.apply_delta(delta)
                    outcomes.append("d-ok")
                except FaultError:
                    outcomes.append("d-fault")
                try:
                    rec = sess.recommend(budget)
                    assert_identical(
                        rec, DesignAdvisor(wl).recommend(budget))
                    outcomes.append("r-ok")
                except FaultError:
                    outcomes.append("r-fault")
            return outcomes
        a, b = run(11), run(11)
        assert a == b
        assert "d-fault" in a and "r-fault" in a and "r-ok" in a
        assert run(12) != a


# ---------------------------------------------------------------------------
# Checkpoint / restore parity
# ---------------------------------------------------------------------------

class TestSnapshotRestore:
    def _drifted(self, workload, pool, faults=None, opt=None):
        sess = AdvisorSession(workload, opt, faults=faults)
        sess.apply(WorkloadDelta(added=(pool[0], pool[1])))
        sess.apply(WorkloadDelta(
            removed=(workload.statements[2].name,),
            reweighted=((workload.statements[0].name, 4.0),)))
        return sess

    def test_restore_equals_fresh_advisor(self, workload, pool, budget):
        sess = self._drifted(workload, pool)
        rec_live = sess.recommend(budget)
        snap = sess.snapshot()
        back = AdvisorSession.restore(snap)
        rec_back = back.recommend(budget)
        fresh = DesignAdvisor(snap.workload).recommend(budget)
        assert_identical(rec_back, fresh)
        assert_identical(rec_back, rec_live)

    def test_restore_without_estimates_still_exact(self, workload, pool,
                                                   budget):
        sess = self._drifted(workload, pool)
        sess.recommend(budget)
        snap = sess.snapshot(include_estimates=False)
        assert snap.estimates == {}
        back = AdvisorSession.restore(snap)
        assert_identical(back.recommend(budget),
                         DesignAdvisor(snap.workload).recommend(budget))

    def test_bytes_round_trip(self, workload, pool, budget):
        sess = self._drifted(workload, pool)
        sess.recommend(budget)
        blob = sess.snapshot().to_bytes()
        assert isinstance(blob, bytes)
        back = AdvisorSession.restore(SessionSnapshot.from_bytes(blob))
        assert_identical(back.recommend(budget),
                         DesignAdvisor(back.workload).recommend(budget))

    def test_from_bytes_rejects_non_snapshot(self):
        """Unframed bytes fail the magic check (SnapshotCorrupt); a
        correctly framed payload that is not a SessionSnapshot still
        raises the original TypeError."""
        import zlib
        with pytest.raises(SnapshotCorrupt, match="magic"):
            SessionSnapshot.from_bytes(pickle.dumps({"nope": 1}))
        payload = pickle.dumps({"nope": 1})
        framed = _SNAP_HEADER.pack(SNAPSHOT_MAGIC, SNAPSHOT_FORMAT_VERSION,
                                   len(payload), zlib.crc32(payload)) \
            + payload
        with pytest.raises(TypeError, match="not a SessionSnapshot"):
            SessionSnapshot.from_bytes(framed)

    def test_snapshot_header_truncation_detected(self, workload):
        blob = AdvisorSession(workload).snapshot().to_bytes()
        for cut in (0, 5, _SNAP_HEADER.size - 1):
            with pytest.raises(SnapshotCorrupt, match="truncated"):
                SessionSnapshot.from_bytes(blob[:cut])
        with pytest.raises(SnapshotCorrupt, match="truncated") as ei:
            SessionSnapshot.from_bytes(blob[:len(blob) // 2])
        assert ei.value.offset == len(blob) // 2

    def test_snapshot_tamper_detected_with_checksums(self, workload):
        blob = bytearray(AdvisorSession(workload).snapshot().to_bytes())
        blob[_SNAP_HEADER.size + 7] ^= 0x40
        with pytest.raises(SnapshotCorrupt, match="checksum") as ei:
            SessionSnapshot.from_bytes(bytes(blob))
        assert ei.value.expected_crc is not None
        assert ei.value.actual_crc is not None
        assert ei.value.expected_crc != ei.value.actual_crc
        # the message carries both sums for the operator
        assert f"{ei.value.expected_crc:#010x}" in str(ei.value)
        assert f"{ei.value.actual_crc:#010x}" in str(ei.value)

    def test_snapshot_version_mismatch_names_both(self, workload):
        blob = AdvisorSession(workload).snapshot().to_bytes()
        magic, version, length, crc = _SNAP_HEADER.unpack_from(blob, 0)
        future = _SNAP_HEADER.pack(magic, version + 41, length, crc) \
            + blob[_SNAP_HEADER.size:]
        with pytest.raises(SnapshotCorrupt) as ei:
            SessionSnapshot.from_bytes(future)
        assert str(version + 41) in str(ei.value)
        assert str(SNAPSHOT_FORMAT_VERSION) in str(ei.value)

    def test_retired_names_survive_restore(self, workload, pool):
        sess = AdvisorSession(workload)
        gone = workload.statements[1]
        sess.apply(WorkloadDelta(removed=(gone.name,)))
        back = AdvisorSession.restore(sess.snapshot())
        with pytest.raises(ValueError, match="cannot be reused"):
            back.apply(WorkloadDelta(added=(gone,)))

    def test_restore_then_keep_drifting(self, workload, pool, budget):
        sess = self._drifted(workload, pool)
        back = AdvisorSession.restore(sess.snapshot())
        delta = WorkloadDelta(added=(pool[4],))
        back.apply(delta)
        fresh = DesignAdvisor(back.workload)
        assert_identical(back.recommend(budget), fresh.recommend(budget))

    def test_compressed_mode_snapshot(self, workload, pool, budget):
        """Snapshots work across the workload-compression outer session:
        the restored outer session recommends `==` a fresh advisor at
        the same compression budget."""
        opt = AdvisorOptions(compression_budget=8)
        sess = self._drifted(workload, pool, opt=opt)
        sess.recommend(budget)
        back = AdvisorSession.restore(sess.snapshot())
        rec = back.recommend(budget)
        fresh = DesignAdvisor(back.workload, opt).recommend(budget)
        assert_identical(rec, fresh)


# ---------------------------------------------------------------------------
# Bounded caches: evictions fire, parity holds
# ---------------------------------------------------------------------------

class TestBoundedSession:
    def test_drift_under_tight_bounds_is_exact(self, workload, pool,
                                               budget):
        opt = AdvisorOptions(**TIGHT)
        sess = AdvisorSession(workload, opt)
        wl = workload
        for i in range(4):
            delta = WorkloadDelta(added=(pool[2 * i], pool[2 * i + 1]),
                                  removed=(wl.statements[i].name,))
            sess.apply(delta)
            wl = wl.apply_delta(delta)
            assert_identical(sess.recommend(budget),
                             DesignAdvisor(wl).recommend(budget))
        st = sess.stats
        # the bounds actually bit — recomputable state was discarded...
        assert st["samplecf_cache_evictions"] > 0
        assert st["universe_evictions"] > 0
        assert st["replay_evictions"] > 0
        # ...and the residents obey their bounds
        assert st["sampled_estimates_cached"] <= TIGHT[
            "samplecf_cache_entries"]
        assert st["samplecf_cache_maxsize"] == TIGHT[
            "samplecf_cache_entries"]
        # the replay bound is a high-water trigger: the store is cleared
        # at the START of the next planner run once over it, so between
        # trims it holds at most one epoch's recordings
        assert st["replay_evictions"] >= 1
        # epoch eviction resets the universe; it regrows freely between
        # resets, so peak is what the bound controls the ORDER of
        assert st["universe_peak_nodes"] >= st["universe_nodes"]

    def test_unbounded_stats_shape(self, workload, budget):
        sess = AdvisorSession(workload)
        sess.recommend(budget)
        st = sess.stats
        assert st["universe_evictions"] == 0
        assert st["replay_evictions"] == 0
        assert "samplecf_cache_evictions" not in st   # plain dict cache


# ---------------------------------------------------------------------------
# Interleaved deltas x evictions x snapshot/restore.  The deterministic
# twin always runs; hypothesis widens the schedule space when installed.
# ---------------------------------------------------------------------------

def _run_interleaving(schema, workload, pool, budget, ops):
    """Execute an op schedule against a tightly-bounded session,
    checkpointing/restoring on demand, asserting exact parity at every
    recommend.  `ops` entries: "delta" | "recommend" | "roundtrip"."""
    opt = AdvisorOptions(**TIGHT)
    sess = AdvisorSession(workload, opt)
    wl, at = workload, 0
    for op in ops:
        if op == "delta" and at < len(pool):
            delta = WorkloadDelta(added=(pool[at],))
            at += 1
            sess.apply(delta)
            wl = wl.apply_delta(delta)
        elif op == "recommend":
            assert_identical(sess.recommend(budget),
                             DesignAdvisor(wl, opt).recommend(budget))
        elif op == "roundtrip":
            sess = AdvisorSession.restore(
                SessionSnapshot.from_bytes(sess.snapshot().to_bytes()))
            assert [s.name for s in sess.workload.statements] == \
                   [s.name for s in wl.statements]
    assert_identical(sess.recommend(budget),
                     DesignAdvisor(wl, opt).recommend(budget))


def test_interleaved_evictions_and_restores_deterministic(
        schema, workload, pool, budget):
    ops = ["delta", "recommend", "delta", "delta", "roundtrip",
           "recommend", "delta", "roundtrip", "delta", "recommend",
           "roundtrip", "recommend"]
    _run_interleaving(schema, workload, pool, budget, ops)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

    def _noop(*a, **k):
        def deco(fn):
            return fn
        return deco
    given = settings = _noop

    class st:             # minimal stand-in so the decorators parse
        @staticmethod
        def data():
            return None


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="property tests need hypothesis")
@settings(max_examples=6, deadline=None)
@given(st.data())
def test_property_interleaved_durability(data):
    """Any interleaving of deltas, evictions (tight bounds make them
    constant) and serialized checkpoint round-trips leaves the session
    bit-identical to a fresh DesignAdvisor."""
    schema = make_tpch_like(scale=0.1, z=0, seed=0)
    wl = make_scaled_workload(schema, n_statements=12, seed=1)
    pool = [dataclasses.replace(s, name=f"h{i:02d}") for i, s in
            enumerate(make_scaled_workload(schema, n_statements=16,
                                           seed=8).statements)]
    base = sum(DesignAdvisor(wl).sizes.size(i)
               for i in base_configuration(schema).indexes)
    ops = data.draw(st.lists(
        st.sampled_from(["delta", "recommend", "roundtrip"]),
        min_size=3, max_size=10))
    _run_interleaving(schema, wl, pool, 0.3 * base, ops)

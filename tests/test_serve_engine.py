"""Serve-engine continuous-batching regressions (no optional deps).

Pins the slot-isolation invariants the fleet advisor service builds on:
mid-flight admission must be invisible to in-flight requests (the
cross-slot KV corruption regression), slots must be reset before reuse,
and retirement must honor EOS / max_tokens / context overflow.  Kept
free of hypothesis/zstandard imports so these regressions run in every
environment (tests/test_runtime.py skips wholesale without them).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.models.rwkv import RWKVConfig
from repro.serve.engine import (EngineConfig, QueueFull, Request,
                                ServeEngine)

TINY = ModelConfig("tiny", "dense", 2, 64, 4, 2, 128, 256, d_head=16)
TINY_RWKV = ModelConfig("tiny-rwkv", "ssm", 2, 64, 4, 4, 128, 256,
                        d_head=16, mixer="rwkv6",
                        rwkv=RWKVConfig(head_size=16))


@pytest.fixture(scope="module")
def params():
    return MD.init_params(jax.random.PRNGKey(0), TINY, jnp.float32)


class TestMidflightAdmission:
    def test_midflight_admission_parity(self, params):
        """THE regression for the cross-slot KV corruption: admitting a
        request while another slot is mid-decode must not perturb the
        in-flight slot's outputs.  Pre-fix, `_admit`'s per-token prefill
        ran `decode_step` without an `active` mask, advancing EVERY
        slot's position and writing pad-token KV into concurrently
        decoding slots' caches — this test fails on that engine."""

        def run(midflight):
            eng = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                         max_len=64))
            eng.submit(Request(uid=0, prompt=[5, 6, 7], max_new_tokens=6))
            if midflight:
                eng.step()
                eng.step()  # uid 0 is now decoding...
                eng.submit(Request(uid=1, prompt=[9, 8, 4],
                                   max_new_tokens=6))  # ...admit mid-flight
            eng.run_until_drained()
            return eng.finished[0].out_tokens

        assert run(midflight=False) == run(midflight=True)

    def test_midflight_admission_parity_recurrent(self):
        """Same invariant for a recurrent mixer: inactive slots' mamba/
        rwkv state must not integrate the pad token (the KV cache is
        self-healing once positions stop advancing; recurrences are not,
        so decode_step masks their updates explicitly)."""
        params = MD.init_params(jax.random.PRNGKey(1), TINY_RWKV,
                                jnp.float32)

        def run(midflight):
            eng = ServeEngine(TINY_RWKV, params,
                              EngineConfig(batch_slots=2, max_len=64))
            eng.submit(Request(uid=0, prompt=[5, 6, 7], max_new_tokens=5))
            if midflight:
                eng.step()
                eng.step()
                eng.submit(Request(uid=1, prompt=[9, 8, 4],
                                   max_new_tokens=5))
            eng.run_until_drained()
            return eng.finished[0].out_tokens

        assert run(midflight=False) == run(midflight=True)

    def test_staggered_admission_and_slot_reuse_parity(self, params):
        """Continuous-batching invariant: with staggered submits forcing
        slot reuse after retirement, every request's outputs equal its
        run-alone outputs (reused slots are reset, prefill is slot-
        isolated)."""
        prompts = [[5, 6, 7], [9, 8], [3, 1, 4, 1], [2, 7], [11, 12, 13],
                   [4, 4]]

        # reference: one engine, one request at a time (drained between)
        ref = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                     max_len=64))
        solo = []
        for uid, p in enumerate(prompts):
            ref.submit(Request(uid=uid, prompt=list(p), max_new_tokens=4))
            ref.run_until_drained()
            solo.append(ref.finished[uid].out_tokens)

        # staggered: submit one per step so admissions interleave with
        # decodes and 6 requests churn through 2 slots
        eng = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                     max_len=64))
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=list(p), max_new_tokens=4))
            eng.step()
        eng.run_until_drained()
        crowd = [eng.finished[uid].out_tokens for uid in range(len(prompts))]
        assert solo == crowd
        # slot_pos is wired to the real per-slot device position
        assert np.array_equal(np.asarray(eng.state["pos"]), eng.slot_pos)


class TestRetirement:
    def test_eos_retirement(self, params):
        """step() retires slots on EOS, not only max_tokens."""
        eng = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                     max_len=64))
        eng.submit(Request(uid=0, prompt=[5, 6, 7], max_new_tokens=8))
        eng.run_until_drained()
        free = eng.finished[0].out_tokens
        assert len(free) == 8
        eos = free[2]  # pretend the third emitted token is EOS
        eng2 = ServeEngine(TINY, params, EngineConfig(batch_slots=2,
                                                      max_len=64,
                                                      eos_id=eos))
        eng2.submit(Request(uid=0, prompt=[5, 6, 7], max_new_tokens=8))
        eng2.run_until_drained()
        got = eng2.finished[0].out_tokens
        k = free.index(eos)
        assert got == free[:k + 1]     # stops AT the first EOS
        assert eng2.finished[0].done
        assert not eng2.finished[0].truncated

    def test_context_overflow_truncates(self, params):
        """A slot whose position reaches max_len retires as truncated
        instead of silently dropping KV writes off the cache."""
        eng = ServeEngine(TINY, params, EngineConfig(batch_slots=1,
                                                     max_len=8))
        eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=64))
        eng.run_until_drained()
        req = eng.finished[0]
        assert req.done and req.truncated
        # prefill wrote len(prompt)-1 positions; each decode step writes
        # one more and emits one token, until the next write would land
        # at max_len
        assert len(req.out_tokens) == 8 - (len(req.prompt) - 1)


class TestAdmissionControl:
    def test_queue_overflow(self, params):
        eng = ServeEngine(TINY, params, EngineConfig(batch_slots=1,
                                                     max_len=32,
                                                     max_queue=2))
        eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
        eng.submit(Request(uid=1, prompt=[3, 4], max_new_tokens=2))
        with pytest.raises(QueueFull):
            eng.submit(Request(uid=2, prompt=[5, 6], max_new_tokens=2))
        eng.step()  # admits uid 0, freeing queue capacity
        eng.submit(Request(uid=2, prompt=[5, 6], max_new_tokens=2))
        eng.run_until_drained()
        assert len(eng.finished) == 3

    def test_oversized_prompt_rejected(self, params):
        eng = ServeEngine(TINY, params, EngineConfig(batch_slots=1,
                                                     max_len=32))
        with pytest.raises(ValueError):
            eng.submit(Request(uid=9, prompt=list(range(40)),
                               max_new_tokens=2))

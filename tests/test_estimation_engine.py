"""Batched estimation engine: exact parity with scalar SampleCF, batched
kernel equality, SampleManager determinism, the planner's greedy-vs-optimal
behavior on small graphs, and the "All" baseline grid scan.

Everything here is deterministic (no hypothesis dependency) so the parity
guarantees run in every environment; the hypothesis property twins live in
tests/test_core_compression.py and tests/test_core_estimation.py.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (METHODS, AdvisorOptions, DesignAdvisor,
                        EstimationEngine, EstimationPlanner, IndexDef,
                        NodeKey, PlannerEngine, SampleManager, State,
                        batched_sample_cf, make_scaled_workload,
                        make_tpch_like, sample_cf)
from repro.core import compression as C
from repro.core import errors as E
from repro.core.estimation_graph import F_GRID, FORCE_ALL_Q, sampling_cost
from repro.core.planner_engine import assert_plan_identical
from repro.core.relation import ColumnDef, Table, rows_per_page
from repro.core.samplecf import full_index_sizes
from repro.core.synopses import MVDef, SynopsisManager


@pytest.fixture(scope="module")
def schema():
    return make_tpch_like(scale=0.2, z=0, seed=0)


def make_targets(method="NS", n=4):
    keys = [
        NodeKey("lineitem", ("l_shipdate",), method),
        NodeKey("lineitem", ("l_extendedprice",), method),
        NodeKey("lineitem", ("l_shipdate", "l_extendedprice"), method),
        NodeKey("lineitem", ("l_shipdate", "l_extendedprice",
                             "l_quantity"), method),
        NodeKey("orders", ("o_orderdate",), method),
        NodeKey("orders", ("o_orderdate", "o_totalprice"), method),
    ]
    return keys[:n]


class TestBatchKernelParity:
    """Exact batch-vs-scalar equality for every *_bytes_batch kernel."""

    @pytest.mark.parametrize("method", list(METHODS))
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_batch_equals_scalar(self, method, seed):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(1, 6))
        n = int(rng.integers(2, 400))
        widths = rng.integers(1, 9, m)
        cols = np.stack([
            rng.integers(0, min(1 << (8 * int(w)), 1 << 62), n)
            for w in widths])
        for rpp in (1, 7, n, rows_per_page(int(widths.sum()))):
            got = C.BATCH_KERNELS[method](cols, widths, rpp)
            want = [C.METHODS[method]._fn(cols[i], int(widths[i]), rpp)
                    for i in range(m)]
            assert got.tolist() == want, (method, rpp)

    @pytest.mark.parametrize("method", list(METHODS))
    def test_batch_empty_columns(self, method):
        cols = np.zeros((3, 0), dtype=np.int64)
        got = C.BATCH_KERNELS[method](cols, np.array([1, 4, 8]), 16)
        assert got.tolist() == [0, 0, 0]

    def test_jax_dispatcher_falls_back_without_x64(self):
        # default jax config is x64-off: int64 codec math is unavailable,
        # so backend="jax" must silently resolve to the numpy kernels
        rng = np.random.default_rng(0)
        cols = rng.integers(0, 1000, (2, 64))
        w = np.array([4, 4])
        a = C.batched_bytes("LDICT", cols, w, 16, backend="numpy")
        b = C.batched_bytes("LDICT", cols, w, 16, backend="jax")
        assert a.tolist() == b.tolist()
        if not C.jax_batch_ready():
            assert EstimationEngine({}, SampleManager({}),
                                    backend="jax").backend == "numpy"

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError):
            EstimationEngine({}, SampleManager({}), backend="tpu")


class TestEnginePlanParity:
    """Acceptance: batched est_bytes byte-identical to scalar sample_cf."""

    def test_execute_matches_execute_scalar(self, schema):
        wl = make_scaled_workload(schema, n_statements=60, seed=0)
        adv = DesignAdvisor(wl, AdvisorOptions.dtac())
        _, _, all_cands = adv._candidate_universe()
        targets = list(DesignAdvisor.estimation_targets(all_cands))
        planner = EstimationPlanner(schema.tables)
        plan = planner.plan(targets, 0.5, 0.9)
        mgr_s = SampleManager(schema.tables, seed=0)
        mgr_b = SampleManager(schema.tables, seed=0)
        ests_s = planner.execute_scalar(plan, mgr_s)
        ests_b = planner.execute(plan, mgr_b)
        assert set(ests_s) == set(ests_b)
        assert any(n.state is State.SAMPLED for n in plan.nodes.values())
        for k, ref in ests_s.items():
            got = ests_b[k]
            assert got.est_bytes == ref.est_bytes, k.label()
            assert got.cf == ref.cf and got.cost_pages == ref.cost_pages
            assert got.method == ref.method and got.index == ref.index

    def test_all_methods_all_fractions(self, schema):
        keys = [NodeKey("lineitem", cols, m)
                for m in METHODS
                for cols in (("l_shipdate",),
                             ("l_returnflag", "l_shipdate"),
                             ("l_shipdate", "l_extendedprice",
                              "l_quantity"))]
        for f in (0.01, 0.10):
            mgr_s = SampleManager(schema.tables, seed=2)
            eng = EstimationEngine(schema.tables,
                                   SampleManager(schema.tables, seed=2))
            ests = eng.estimate_batch(keys, f)
            for k in keys:
                ref = sample_cf(mgr_s, IndexDef(k.table, k.cols, k.method),
                                f)
                assert ests[k].est_bytes == ref.est_bytes, (k.label(), f)
                assert ests[k].cf == ref.cf

    def test_estimate_sizes_batched_equals_scalar(self, schema):
        wl = make_scaled_workload(schema, n_statements=40, seed=1)
        adv_b = DesignAdvisor(wl, AdvisorOptions.dtac())
        adv_s = DesignAdvisor(wl, dataclasses.replace(
            AdvisorOptions.dtac(), use_batched_estimation=False))
        _, _, cands_b = adv_b._candidate_universe()
        _, _, cands_s = adv_s._candidate_universe()
        cost_b, plan_b, ns_b, nd_b = adv_b.estimate_sizes(cands_b)
        cost_s, plan_s, ns_s, nd_s = adv_s.estimate_sizes(cands_s)
        assert (cost_b, ns_b, nd_b) == (cost_s, ns_s, nd_s)
        for idx in cands_b:
            if idx.compression is not None:
                assert adv_b.sizes.size(idx) == adv_s.sizes.size(idx)
        assert adv_b.sizes.fallback_hits == 0

    def test_mv_index_size_matches_scalar_reference(self, schema):
        samples = SampleManager(schema.tables, seed=0)
        syn = SynopsisManager(schema, samples)
        mv = MVDef("mv_ship", "lineitem", group_by=("l_shipdate",))
        est = syn.mv_index_size(mv, ("l_shipdate",), "LDICT", 0.05)
        # scalar reference: sample_cf on the MV sample as its own table
        smv, n_est = syn.mv_sample(mv, 0.05)
        ref = sample_cf(SampleManager({smv.name: smv}),
                        IndexDef(smv.name, ("l_shipdate",), "LDICT"),
                        1.0, sample_table=smv)
        assert est.cf == ref.cf and est.cost_pages == ref.cost_pages
        w = smv.col_by_name["l_shipdate"].width
        assert est.est_bytes == ref.cf * C.uncompressed_payload_bytes(
            int(n_est), [w])

    def test_engine_counters(self, schema):
        keys = make_targets("LDICT", 6)
        eng = EstimationEngine(schema.tables,
                               SampleManager(schema.tables, seed=0))
        eng.estimate_batch(keys, 0.05)
        assert eng.batch_calls == 2          # lineitem + orders groups
        assert eng.targets_estimated == 6


class TestSampleManager:
    def test_same_seed_identical_samples(self, schema):
        a = SampleManager(schema.tables, seed=7)
        b = SampleManager(schema.tables, seed=7)
        for tname in ("lineitem", "orders"):
            sa = a.get_sample(tname, 0.05)
            sb = b.get_sample(tname, 0.05)
            assert sa.nrows == sb.nrows
            for c in sa.columns:
                assert np.array_equal(sa.values[c.name], sb.values[c.name])

    def test_different_seed_differs(self, schema):
        a = SampleManager(schema.tables, seed=0).get_sample("lineitem", 0.05)
        b = SampleManager(schema.tables, seed=1).get_sample("lineitem", 0.05)
        assert not all(np.array_equal(a.values[c.name], b.values[c.name])
                       for c in a.columns)

    def test_sampling_amortized_across_engine_targets(self, schema):
        """§4.1: sampling_calls stays flat per (table, f), however many
        targets share it — through the batched engine too."""
        mgr = SampleManager(schema.tables, seed=0)
        eng = EstimationEngine(schema.tables, mgr)
        li = [NodeKey("lineitem", cols, m)
              for m in ("NS", "LDICT", "RLE")
              for cols in (("l_shipdate",), ("l_shipdate", "l_quantity"))]
        eng.estimate_batch(li, 0.05)
        assert mgr.sampling_calls == 1
        eng.estimate_batch(li, 0.05)
        assert mgr.sampling_calls == 1       # cached sample reused
        eng.estimate_batch(li, 0.025)
        assert mgr.sampling_calls == 2       # new f => one new draw
        eng.estimate_batch(make_targets("NS", 6), 0.05)
        assert mgr.sampling_calls == 3       # orders joins in once

    @pytest.mark.parametrize("method",
                             [m for m in METHODS if m != "GDICT"])
    def test_samplecf_within_fitted_error_model(self, schema, method):
        """Ground truth (full_index_sizes) vs SampleCF on a fixed seed:
        the bias-corrected estimate's error stays within a few fitted
        standard deviations of the §5.1 error model."""
        f = 0.05
        mgr = SampleManager(schema.tables, seed=3)
        li = schema.tables["lineitem"]
        idx = IndexDef("lineitem", ("l_shipdate", "l_returnflag"),
                       compression=method)
        _, true = full_index_sizes(li, idx)
        est = sample_cf(mgr, idx, f)
        rv = E.samplecf_error(method, f)
        assert abs(est.est_bytes / true - 1) <= max(4 * rv.std, 0.03)

    def test_gdict_samplecf_overestimates(self, schema):
        """GDICT is the known exception to linear CF scaling (NDV does not
        scale with the sample); the App. B Adaptive Estimator now prices
        the full dictionary directly.  Flipped from a characterization
        test (estimate pinned between truth and the uncompressed size) to
        a tolerance assertion in the spirit of the paper's ~6% AE error
        (Table 1), across the whole f grid."""
        li = schema.tables["lineitem"]
        idx = IndexDef("lineitem", ("l_shipdate", "l_returnflag"),
                       compression="GDICT")
        _, true = full_index_sizes(li, idx)
        for f in F_GRID:
            mgr = SampleManager(schema.tables, seed=3)
            est = sample_cf(mgr, idx, f)
            assert abs(est.est_bytes / true - 1) <= 0.10, f


class TestPlannerEngine:
    """Batched §5.2 planner engine vs the scalar greedy reference."""

    def advisor_targets(self, schema, n_statements=60, seed=0):
        wl = make_scaled_workload(schema, n_statements=n_statements,
                                  seed=seed)
        adv = DesignAdvisor(wl, AdvisorOptions.dtac())
        _, _, all_cands = adv._candidate_universe()
        return list(DesignAdvisor.estimation_targets(all_cands))

    def test_greedy_batch_plan_identical_over_grid(self, schema):
        targets = self.advisor_targets(schema)
        planner = EstimationPlanner(schema.tables)
        batched = planner.engine.greedy_batch(targets, 0.5, 0.9, F_GRID)
        assert any(p.n_deduced() for p in batched)  # non-trivial plans
        for f, got in zip(F_GRID, batched):
            assert_plan_identical(
                planner.greedy_scalar(targets, f, 0.5, 0.9), got)

    def test_plan_matches_plan_scalar(self, schema):
        targets = self.advisor_targets(schema)
        planner = EstimationPlanner(schema.tables)
        for e, q in ((0.5, 0.9), (0.05, 0.99), (1.0, 0.8)):
            assert_plan_identical(planner.plan_scalar(targets, e, q),
                                  planner.plan(targets, e, q))

    def test_plan_all_sampled_matches_scalar(self, schema):
        targets = make_targets("LDICT", 4)
        planner = EstimationPlanner(schema.tables)
        for e, q in ((0.2, 0.9), (0.05, 0.99)):
            got = planner.plan_all_sampled(targets, e, q)
            planner.use_engine = False
            ref = planner.plan_all_sampled(targets, e, q)
            planner.use_engine = True
            assert_plan_identical(ref, got)

    def test_force_all_q_parity(self, schema):
        targets = make_targets("NS", 6)
        planner = EstimationPlanner(schema.tables)
        for f in F_GRID:
            got = planner.engine.greedy_batch(targets, 0.3, FORCE_ALL_Q,
                                              (f,))[0]
            assert_plan_identical(
                planner.greedy_scalar(targets, f, 0.3, FORCE_ALL_Q), got)
            assert got.n_deduced() == 0  # q > 1 forces sampling everywhere

    def test_existing_exact_nodes(self, schema):
        existing = {NodeKey("lineitem", ("l_shipdate",), "NS"): 12345.0,
                    NodeKey("lineitem",
                            ("l_shipdate", "l_extendedprice"), "NS"): 99.0}
        planner = EstimationPlanner(schema.tables, existing=existing)
        targets = make_targets("NS", 4)
        for f in (0.01, 0.05):
            got = planner.engine.greedy_batch(targets, 0.5, 0.9, (f,))[0]
            assert_plan_identical(
                planner.greedy_scalar(targets, f, 0.5, 0.9), got)
            for k, size in existing.items():
                assert got.nodes[k].state is State.EXACT
                assert got.nodes[k].exact_bytes == size

    def test_graph_built_once_across_runs(self, schema):
        targets = make_targets("NS", 6)
        eng = PlannerEngine(schema.tables)
        eng.greedy_batch(targets, 0.5, 0.9, F_GRID)
        eng.greedy_batch(targets, 0.1, 0.99, F_GRID)
        eng.plan_batch(targets, 0.5, 0.9)
        assert eng.graph_builds == 1     # shared deduction graph reused
        assert eng.batch_runs == 3

    def test_estimate_sizes_planner_toggle_parity(self, schema):
        wl = make_scaled_workload(schema, n_statements=40, seed=1)
        adv_b = DesignAdvisor(wl, AdvisorOptions.dtac())
        adv_s = DesignAdvisor(wl, dataclasses.replace(
            AdvisorOptions.dtac(), use_batched_planner=False))
        _, _, cands_b = adv_b._candidate_universe()
        _, _, cands_s = adv_s._candidate_universe()
        cost_b, plan_b, ns_b, nd_b = adv_b.estimate_sizes(cands_b)
        cost_s, plan_s, ns_s, nd_s = adv_s.estimate_sizes(cands_s)
        assert (cost_b, ns_b, nd_b) == (cost_s, ns_s, nd_s)
        assert plan_b.f == plan_s.f
        for idx in cands_b:
            if idx.compression is not None:
                assert adv_b.sizes.size(idx) == adv_s.sizes.size(idx)

    def test_backend_gating(self, schema):
        # default jax config is x64-off: float64 scoring is unavailable,
        # so backend="jax" must silently resolve to numpy
        if not C.jax_batch_ready():
            assert PlannerEngine(schema.tables,
                                 backend="jax").backend == "numpy"
        with pytest.raises(ValueError):
            PlannerEngine(schema.tables, backend="tpu")


class TestGreedyVsOptimal:
    """Small graphs (<= 6 targets): greedy within the paper's bound,
    (e, q) satisfied whenever a plan is feasible, infeasibility flagged."""

    CASES = [
        ("NS", 0.8, 0.85), ("NS", 0.3, 0.9), ("LDICT", 1.0, 0.8),
        ("LDICT", 0.5, 0.9),
    ]

    @pytest.mark.parametrize("method,e,q", CASES)
    def test_optimal_not_worse_and_bounded_by_all_sampled(
            self, schema, method, e, q):
        planner = EstimationPlanner(schema.tables)
        targets = make_targets(method, 6)
        for f in (0.05, 0.10):
            g = planner.greedy(targets, f, e, q)
            o = planner.optimal(targets, f, e, q)
            all_cost = sum(sampling_cost(schema.tables[t.table], t, f)
                           for t in targets)
            assert o.total_cost <= g.total_cost + 1e-9
            assert g.total_cost <= all_cost + 1e-9   # §5.2 greedy bound
            if o.feasible:
                for t in targets:
                    assert E.satisfies(o.nodes[t].rv, e, q)
            if g.feasible:
                for t in targets:
                    assert E.satisfies(g.nodes[t].rv, e, q)

    def test_feasible_case_agrees(self, schema):
        planner = EstimationPlanner(schema.tables)
        targets = make_targets("NS", 4)
        g = planner.greedy(targets, 0.05, 0.8, 0.85)
        o = planner.optimal(targets, 0.05, 0.8, 0.85)
        assert g.feasible and o.feasible

    @pytest.mark.parametrize("method", ["NS", "LDICT"])
    def test_optimal_plan_executes_through_batched_engine(self, schema,
                                                          method):
        """App. D plans run through the batched EstimationEngine exactly
        like greedy plans: byte-identical to the scalar execute path."""
        planner = EstimationPlanner(schema.tables)
        targets = make_targets(method, 6)
        plan = planner.optimal(targets, 0.05, 0.8, 0.85)
        mgr_s = SampleManager(schema.tables, seed=0)
        mgr_b = SampleManager(schema.tables, seed=0)
        ests_s = planner.execute_scalar(plan, mgr_s)
        ests_b = planner.execute(plan, mgr_b)
        assert set(ests_s) == set(ests_b)
        assert any(n.state is State.SAMPLED for n in plan.nodes.values())
        for k, ref in ests_s.items():
            got = ests_b[k]
            assert (got.est_bytes == ref.est_bytes and got.cf == ref.cf
                    and got.cost_pages == ref.cost_pages
                    and got.method == ref.method), k.label()

    def test_optimal_execute_cached_matches_scalar(self, schema):
        """The session's (NodeKey, f)-cached executor resolves optimal
        plans byte-identically too, and repeated calls hit the cache."""
        planner = EstimationPlanner(schema.tables)
        targets = make_targets("NS", 5)
        plan = planner.optimal(targets, 0.05, 0.8, 0.85)
        mgr_s = SampleManager(schema.tables, seed=0)
        mgr_c = SampleManager(schema.tables, seed=0)
        cache = {}
        ests_s = planner.execute_scalar(plan, mgr_s)
        ests_c = planner.execute_cached(plan, mgr_c, cache)
        n_cached = len(cache)
        assert n_cached == sum(1 for n in plan.nodes.values()
                               if n.state is State.SAMPLED)
        for k, ref in ests_s.items():
            assert ests_c[k].est_bytes == ref.est_bytes
        # second execution: all sampled estimates come from the cache
        ests_c2 = planner.execute_cached(plan, mgr_c, cache)
        assert len(cache) == n_cached
        for k, ref in ests_c.items():
            assert ests_c2[k].est_bytes == ref.est_bytes

    def test_infeasible_flagged_by_both(self, schema):
        """e/q so tight that even SampleCF cannot meet the bound for
        ORD-DEP methods: every plan must be flagged infeasible."""
        planner = EstimationPlanner(schema.tables)
        targets = make_targets("LDICT", 4)
        assert not E.satisfies(E.samplecf_error("LDICT", 0.10), 0.05, 0.99)
        g = planner.greedy(targets, 0.10, 0.05, 0.99)
        assert not g.feasible
        o = planner.optimal(targets, 0.10, 0.05, 0.99)
        assert not o.feasible
        p = planner.plan(targets, 0.05, 0.99)
        assert not p.feasible                # grid scan can't rescue it


class TestAllSampledBaseline:
    """Regression for the estimate_sizes "All" loop: the f grid must
    actually be scanned against the caller's (e, q) — the old code broke
    on F_GRID[0] unconditionally (q>1 plans are never feasible)."""

    def test_scans_grid_to_satisfy_constraint(self, schema):
        e, q = 0.2, 0.9
        # LDICT sampling error at the smallest fractions violates (e, q):
        # the intended behavior picks the first f on the grid that works
        expected_f = next(f for f in F_GRID
                          if E.satisfies(E.samplecf_error("LDICT", f), e, q))
        assert expected_f > F_GRID[0]        # the scan is non-trivial
        planner = EstimationPlanner(schema.tables)
        plan = planner.plan_all_sampled(make_targets("LDICT", 4), e, q)
        assert plan.f == expected_f
        assert plan.feasible
        assert plan.n_deduced() == 0
        assert plan.n_sampled() == 4

    def test_infeasible_falls_back_to_cheapest(self, schema):
        planner = EstimationPlanner(schema.tables)
        plan = planner.plan_all_sampled(make_targets("LDICT", 4), 0.05, 0.99)
        assert not plan.feasible
        assert plan.f == F_GRID[0]           # cheapest all-sampled plan
        assert plan.n_deduced() == 0

    def test_advisor_all_baseline_uses_grid(self, schema):
        wl = make_scaled_workload(schema, n_statements=30, seed=0)
        adv = DesignAdvisor(wl, AdvisorOptions(use_deduction=False,
                                               e=0.2, q=0.9))
        _, _, cands = adv._candidate_universe()
        cost, plan, n_s, n_d = adv.estimate_sizes(cands)
        assert n_d == 0                      # "All" never deduces
        assert plan.f > F_GRID[0]            # grid actually scanned
        assert plan.feasible
        assert cost > 0

    def test_forced_sampling_matches_manual_greedy(self, schema):
        """plan_all_sampled(f) states match greedy under q>1 at the same
        f (the forcing trick), with feasibility re-judged honestly."""
        from repro.core.estimation_graph import FORCE_ALL_Q
        planner = EstimationPlanner(schema.tables)
        targets = make_targets("LDICT", 4)
        plan = planner.plan_all_sampled(targets, 0.2, 0.9)
        manual = planner.greedy(targets, plan.f, 0.2, FORCE_ALL_Q)
        assert plan.states() == manual.states()
        assert not manual.feasible           # q>1 is unsatisfiable...
        assert plan.feasible                 # ...but the real q holds


class TestReplacedFractionBatch:
    def test_bit_identical_to_scalar(self, schema):
        """The batched F(I_X, Y) stats equal the scalar ones exactly —
        both fill the same per-table cache, so any drift would leak
        between the scalar and batched ColExt deduction paths."""
        import copy

        from repro.core import deduction as D
        table = schema.tables["lineitem"]
        cols = [c.name for c in table.columns]
        for w in (1, 2, 3):
            for start in range(len(cols) - w + 1):
                ic = tuple(cols[start:start + w])
                fresh = copy.copy(table)
                fresh._stats_cache = {
                    k: v for k, v in table._stats_cache.items()
                    if k[0] != "ded_rf"}
                got = D.replaced_fraction_batch(fresh, ic, list(ic)).tolist()
                want = [D.replaced_fraction(table, ic, c) for c in ic]
                assert got == want, ic


class TestSinglePageClosedForms:
    """The engine's single-page LDICT/PREFIX closed forms vs the kernels."""

    def test_single_page_matches_kernel(self):
        rng = np.random.default_rng(0)
        n = 50
        t = Table("t", [ColumnDef("a", 4), ColumnDef("b", 2)], {
            "a": rng.integers(0, 9, n), "b": rng.integers(0, 500, n)})
        mgr = SampleManager({"t": t}, seed=0)
        specs = [(("a", "b"), m) for m in ("LDICT", "PREFIX", "RLE")]
        # f=1.0 -> sample is the table; rpp >> n -> single page everywhere
        got = batched_sample_cf(t, t, specs, 1.0)
        for (cols, m), est in zip(specs, got):
            ref = sample_cf(mgr, IndexDef("t", cols, m), 1.0,
                            sample_table=t)
            assert est.est_bytes == ref.est_bytes, m
            assert est.cf == ref.cf

    def test_multi_page_boundary(self):
        """n just above/below rows-per-page crosses the closed-form
        boundary; both sides must match the scalar path exactly."""
        rng = np.random.default_rng(1)
        rpp = rows_per_page(8 + 8)   # two 8-byte columns
        for n in (rpp - 1, rpp, rpp + 1, 3 * rpp + 5):
            t = Table("t", [ColumnDef("a", 8), ColumnDef("b", 8)], {
                "a": rng.integers(0, 7, n), "b": rng.integers(0, 1 << 40, n)})
            mgr = SampleManager({"t": t}, seed=0)
            for m in METHODS:
                est = batched_sample_cf(t, t, [(("a", "b"), m)], 1.0)[0]
                ref = sample_cf(mgr, IndexDef("t", ("a", "b"), m), 1.0,
                                sample_table=t)
                assert est.est_bytes == ref.est_bytes, (m, n)
